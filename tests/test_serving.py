"""Continuous-batching serving runtime: paged cache accounting, scheduler
admission/retirement/preemption, the cache splice, flash-decode length
masking, and engine end-to-end equality with sequential generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, SSMConfig
from repro.kernels import ops
from repro.models import registry
from repro.runtime.serving import (PagedKVCacheManager, Request,
                                   ServingEngine, Scheduler, Status,
                                   cache_insert)

# ---------------------------------------------------------------------------
# paged cache manager (pure host logic)
# ---------------------------------------------------------------------------


def test_cache_allocate_extend_free():
    m = PagedKVCacheManager(num_pages=8, page_size=4)
    assert m.allocate(0, 9)                   # 3 pages
    assert m.page_table(0) == (0, 1, 2)
    assert m.free_pages == 5
    assert m.extend(0, 12)                    # still 3 pages
    assert m.free_pages == 5
    assert m.extend(0, 13)                    # page boundary -> 4 pages
    assert m.free_pages == 4
    assert m.length(0) == 13
    m.free(0)
    assert m.free_pages == 8 and m.page_table(0) == ()


def test_cache_refuses_oversubscription_and_reuses_pages():
    m = PagedKVCacheManager(num_pages=4, page_size=4)
    assert m.allocate(0, 8)                   # pages 0,1
    assert m.allocate(1, 8)                   # pages 2,3
    assert not m.allocate(2, 1)               # no pages left, nothing taken
    assert not m.extend(0, 9)                 # growth refused, slot keeps 2
    assert m.page_table(0) == (0, 1)
    m.free(1)
    assert m.allocate(2, 5)                   # freed pages reused
    assert set(m.page_table(2)) == {2, 3}
    assert abs(m.utilization() - 1.0) < 1e-9


def test_cache_double_allocate_raises():
    m = PagedKVCacheManager(num_pages=4, page_size=4)
    assert m.allocate(0, 4)
    with pytest.raises(ValueError):
        m.allocate(0, 4)
    with pytest.raises(ValueError):
        m.extend(3, 8)                        # never allocated


# ---------------------------------------------------------------------------
# scheduler (no model needed)
# ---------------------------------------------------------------------------

def _req(uid, plen=4, max_new=4, eos=None):
    return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=max_new, eos_id=eos)


def test_scheduler_fifo_admission_and_slot_assignment():
    s = Scheduler(2, PagedKVCacheManager(64, 4))
    sts = [s.submit(_req(i)) for i in range(3)]
    admitted = s.schedule()
    assert [st.request.uid for st in admitted] == [0, 1]
    assert [st.slot for st in admitted] == [0, 1]
    assert sts[2].status == Status.WAITING
    assert s.schedule() == []                 # no free slots


def test_scheduler_retirement_max_new_and_slot_reuse():
    s = Scheduler(1, PagedKVCacheManager(64, 4))
    s.submit(_req("a", max_new=2))
    s.submit(_req("b", max_new=1))
    (sta,) = s.schedule()
    assert s.on_token(0, 7) == []             # token 1 of 2
    deps = s.on_token(0, 8)                   # token 2 -> retire
    assert deps == [(0, sta)]
    assert sta.done and sta.finish_reason == "max_new_tokens"
    assert sta.generated == [7, 8]
    (stb,) = s.schedule()                     # slot 0 reused
    assert stb.request.uid == "b" and stb.slot == 0


def test_scheduler_eos_retirement():
    s = Scheduler(1, PagedKVCacheManager(64, 4))
    s.submit(_req("a", max_new=10, eos=42))
    (st,) = s.schedule()
    assert s.on_token(0, 5) == []
    deps = s.on_token(0, 42)
    assert deps == [(0, st)] and st.finish_reason == "eos"
    assert st.generated == [5, 42]            # eos token included


def test_scheduler_preempts_youngest_on_page_exhaustion():
    # 2 slots, 6 pages of 4 rows; two prompts of 8 rows reserve 3 pages each
    # (prompt + first-token row) -> pool full; first growth past the page
    # boundary must evict the *younger* sequence, not the grower
    s = Scheduler(2, PagedKVCacheManager(6, 4))
    old = s.submit(_req("old", plen=8, max_new=8))
    young = s.submit(_req("young", plen=8, max_new=8))
    assert len(s.schedule()) == 2
    for tok in range(3):                      # rows 9..11 stay in page 3
        assert s.on_token(old.slot, tok) == []
    deps = s.on_token(old.slot, 99)           # row 12 -> needs a 4th page
    assert [st.request.uid for _, st in deps] == ["young"]
    assert young.status == Status.WAITING and young.generated == []
    assert s.stats["preempted"] == 1
    assert old.status == Status.RUNNING       # oldest never evicted
    assert s.schedule() == []                 # still no room for young
    # run old to completion: generated=4 so far, 4 more to max_new=8
    for tok in range(4, 8):
        deps = s.on_token(old.slot, tok)
    assert old.done and deps == [(0, old)]
    # preempted request re-admits once the pool drains
    assert [st.request.uid for st in s.schedule()] == ["young"]
    assert young.prefills == 2


def test_scheduler_rejects_never_fitting_request():
    s = Scheduler(2, PagedKVCacheManager(4, 4))   # pool: 16 rows
    with pytest.raises(ValueError):
        s.submit(_req("x", plen=20, max_new=4))


def test_scheduler_rejects_request_longer_than_slot_arena():
    # pool is wide enough (2 slots x 16 rows) but one slot is only 16 deep:
    # a 20-row sequence would scatter past max_seq and silently corrupt
    s = Scheduler(2, PagedKVCacheManager(2, 16), max_len=16)
    with pytest.raises(ValueError):
        s.submit(_req("x", plen=4, max_new=16))
    s.submit(_req("ok", plen=4, max_new=12))      # exactly 16 rows: fine


# ---------------------------------------------------------------------------
# cache splice (fused-batch leaf handling)
# ---------------------------------------------------------------------------

def test_cache_insert_handles_plain_and_fused_batch_dims():
    L, slots, S, kvh, hd, nh = 2, 3, 8, 2, 4, 5
    big = {
        "kv": jnp.zeros((L, slots, S, kvh, hd)),
        "ssm": jnp.zeros((L, slots * nh, 7)),     # batch fused with heads
    }
    one = {
        "kv": jnp.ones((L, 1, S, kvh, hd)),
        "ssm": jnp.full((L, 1 * nh, 7), 2.0),
    }
    out = jax.jit(cache_insert)(big, one, jnp.int32(1))
    kv = np.asarray(out["kv"])
    ssm = np.asarray(out["ssm"])
    assert kv[:, 1].min() == 1.0 and kv[:, [0, 2]].max() == 0.0
    assert ssm[:, nh:2 * nh].min() == 2.0
    assert ssm[:, :nh].max() == 0.0 and ssm[:, 2 * nh:].max() == 0.0


# ---------------------------------------------------------------------------
# flash-decode: per-slot length masking vs naive oracle
# ---------------------------------------------------------------------------

def _naive_decode_attn(q, k, v, lengths, window=None):
    b, h, hd = q.shape
    _, s, kvh, _ = k.shape
    g = h // kvh
    qh = q.reshape(b, kvh, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bkgh,bskh->bkgs", qh,
                    k.astype(jnp.float32)) * hd ** -0.5
    kpos = jnp.arange(s)
    mask = kpos[None] < lengths[:, None]
    if window is not None:
        mask &= kpos[None] >= (lengths - window)[:, None]
    sc = jnp.where(mask[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)


@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_decode_matches_naive(mode, window):
    rng = np.random.default_rng(0)
    B, H, KVH, S, hd = 3, 8, 2, 40, 16
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    lengths = jnp.asarray([1, 17, 40], jnp.int32)   # incl. vl=1 and vl=S
    got = ops.flash_decode(q, k, v, lengths=lengths, window=window,
                           mode=mode, bk=16)
    want = _naive_decode_attn(q, k, v, lengths, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_decode_none_lengths_attends_everything():
    rng = np.random.default_rng(1)
    B, H, KVH, S, hd = 2, 4, 4, 24, 8                # MHA (G=1) case
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    got = ops.flash_decode(q, k, v, mode="ref")
    want = _naive_decode_attn(q, k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

TINY = ArchConfig(name="tiny-dense", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=97, head_dim=8,
                  param_dtype="float32", act_dtype="float32", max_seq=64)
TINY_SSM = ArchConfig(name="tiny-ssm", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=97,
                      ssm=SSMConfig(d_state=8, headdim=8, chunk=16),
                      param_dtype="float32", act_dtype="float32",
                      subquadratic=True, max_seq=64)


@pytest.fixture(scope="module")
def tiny_model():
    model = registry.build_model(TINY)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return model, params


def _reference(model, params, prompt, gen, max_seq=64):
    """Sequential single-request generation: the ground truth the
    continuous-batching engine must reproduce token-for-token."""
    cache = model.init_cache(1, max_seq)
    logits, cache = jax.jit(model.prefill)(
        params, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(logits[0]))]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    tok = jnp.asarray([toks[0]], jnp.int32)
    step = jax.jit(model.decode_step)
    for _ in range(gen - 1):
        logits, cache = step(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(tok[0]))
        pos = pos + 1
    return np.array(toks, np.int32)


@pytest.mark.parametrize("depth", [0, 2])
def test_engine_matches_sequential_reference(tiny_model, depth):
    """Staggered admission (slots < requests), mixed prompt/gen lengths,
    both dispatch depths -> token-exact vs sequential generation."""
    model, params = tiny_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, TINY.vocab, n).astype(np.int32)
               for n in (5, 9, 7, 12)]
    gens = [8, 6, 10, 7]
    want = [_reference(model, params, p, g) for p, g in zip(prompts, gens)]
    eng = ServingEngine(model, TINY, params, max_slots=2, max_seq=64,
                        depth=depth)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=g))
    out = eng.run(max_steps=500)
    for i in range(4):
        np.testing.assert_array_equal(out[i], want[i])
    assert eng.scheduler.stats["admitted"] == 4
    assert eng.stats["tokens_out"] == sum(gens)


def test_engine_preemption_recompute_is_exact(tiny_model):
    """Undersized page pool: sequences are evicted mid-decode and recomputed
    — outputs must still equal the sequential reference."""
    model, params = tiny_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, TINY.vocab, n).astype(np.int32)
               for n in (10, 12, 11)]
    want = [_reference(model, params, p, 14) for p in prompts]
    eng = ServingEngine(model, TINY, params, max_slots=3, max_seq=64,
                        depth=2, page_size=4, num_pages=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=14))
    out = eng.run(max_steps=2000)
    for i in range(3):
        np.testing.assert_array_equal(out[i], want[i])
    assert eng.scheduler.stats["preempted"] > 0     # pressure actually hit


def test_engine_same_batch_admission_eviction(tiny_model):
    """Regression: an admission's first-token row reservation can evict a
    later admission of the *same* schedule() batch before it was prefilled
    — the admit loop must skip it, not crash on slot=None."""
    model, params = tiny_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, TINY.vocab, 3).astype(np.int32)
               for _ in range(2)]
    want = [_reference(model, params, p, 3) for p in prompts]
    # 4 pages of 1 row: both admissions take the whole pool, so request 0's
    # first-token reservation must evict not-yet-prefilled request 1
    eng = ServingEngine(model, TINY, params, max_slots=2, max_seq=16,
                        depth=2, page_size=1, num_pages=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=3))
    out = eng.run(max_steps=200)
    for i in range(2):
        np.testing.assert_array_equal(out[i], want[i])


def test_engine_eos_stops_at_first_occurrence(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, TINY.vocab, 10).astype(np.int32)
    ref = _reference(model, params, prompt, 12)
    eos = int(ref[4])
    first = int(np.argmax(ref == eos))              # eos may repeat earlier
    eng = ServingEngine(model, TINY, params, max_slots=2, max_seq=64)
    eng.submit(Request(uid="e", prompt=prompt, max_new_tokens=12,
                       eos_id=eos))
    out = eng.run(max_steps=500)
    np.testing.assert_array_equal(out["e"], ref[:first + 1])


def test_engine_ssm_family(tiny_model):
    """The slot splice + masked decode also hold for recurrent-state
    caches (fused batch·head leaves)."""
    model = registry.build_model(TINY_SSM)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, TINY_SSM.vocab, n).astype(np.int32)
               for n in (6, 9)]
    want = [_reference(model, params, p, 6) for p in prompts]
    eng = ServingEngine(model, TINY_SSM, params, max_slots=2, max_seq=64,
                        depth=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    out = eng.run(max_steps=200)
    for i in range(2):
        np.testing.assert_array_equal(out[i], want[i])
