"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement).  Also one decode step per family through the same cache the
prefill filled — the serving-path contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry

ARCHS = list(registry.ARCH_NAMES)


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patch_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    bundle = registry.build(arch, reduced=True)
    cfg = bundle.cfg
    params = jax.jit(bundle.model.init)(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_and_grad(p, b):
        loss, aux = bundle.model.loss_fn(p, b)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_and_grad))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    bundle = registry.build(arch, reduced=True)
    cfg = bundle.cfg
    model = bundle.model
    b, s = 2, 16
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    batch = _batch(cfg, b, s)
    cache = model.init_cache(b, s + 8)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = batch["frames"]
    if cfg.family == "vlm":
        extras["patch_embeds"] = batch["prefix_embeds"]
        cache = model.init_cache(b, s + 8 + cfg.n_patch_tokens)
    logits, cache = jax.jit(
        lambda p, t, c: model.prefill(p, t, c, **extras))(
            params, batch["tokens"], cache)
    assert logits.shape == (b, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN prefill logits"

    pos0 = s + (cfg.n_patch_tokens if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((b,), pos0, jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, tok, cache, pos)
    assert logits2.shape == (b, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2))), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-2.7b"])
def test_decode_matches_prefill_next_token(arch):
    """Greedy next-token from (prefill then decode_step) must equal the
    next-token from prefilling the extended sequence — KV-cache/state
    correctness end-to-end."""
    bundle = registry.build(arch, reduced=True)
    model = bundle.model
    cfg = bundle.cfg
    b, s = 2, 12
    params = jax.jit(model.init)(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    logits_p, cache = jax.jit(model.prefill)(params, toks,
                                             model.init_cache(b, s + 4))
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, _ = jax.jit(model.decode_step)(
        params, nxt, cache, jnp.full((b,), s, jnp.int32))

    ext = jnp.concatenate([toks, nxt[:, None]], 1)
    logits_f, _ = jax.jit(model.prefill)(params, ext,
                                         model.init_cache(b, s + 4))
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_f),
                               rtol=5e-2, atol=5e-2)


def test_grid_cells_accounting():
    """32 runnable + 8 documented skips == 40 assigned cells."""
    cells = list(registry.grid_cells(include_skips=True))
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 32
    assert len(skipped) == 8
    for name, shape, ok, why in skipped:
        assert shape == "long_500k"
        assert "sub-quadratic" in why


def test_all_archs_have_input_specs():
    for arch in ARCHS:
        bundle = registry.build(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            specs = bundle.input_specs(shape)
            assert specs, f"{arch}/{shape}"
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
