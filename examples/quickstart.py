"""Quickstart: the paper's vector-unit semantics + a 2-minute LM train.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --- 1. The paper's core mechanisms, as library calls --------------------
from repro.core import masking, reduction, vrf

print("== RVV 1.0 byte layout (paper §IV) ==")
mem = jnp.arange(64, dtype=jnp.uint8)            # a register's memory image
lane_view = vrf.shuffle(mem, eew=2, lanes=4)     # 16-bit elements, 4 lanes
print("element 5 (bytes 10:12) lives in lane", 5 % 4,
      "->", np.asarray(lane_view[1, 2:4]))
back = vrf.deshuffle(lane_view, eew=2, lanes=4)
assert (np.asarray(back) == np.asarray(mem)).all()

print("\n== 3-step hierarchical reduction (paper §V.e) ==")
x = jnp.arange(128.0)
total = reduction.lane_tree_reduce(x, lanes=16, eew_bytes=8)
print("lane_tree_reduce ==", float(total), "(flat sum:", float(x.sum()), ")")
print("ideal cycles @16 lanes:", reduction.ideal_cycles(1024, 16))

print("\n== Mask unit (paper §IV.D.1) ==")
bits = jnp.asarray([True, False] * 32)
packed = masking.pack_bits(bits, 64)
img = jnp.zeros(64, jnp.uint8).at[:packed.size].set(packed)
lanes_view = vrf.shuffle(img, eew=4, lanes=4)    # mask reg written at EEW=4
pred = masking.mask_unit(lanes_view, stored_eew=4, lanes=4, num_elems=64)
print("lane 0 predicates (elements 0,4,8,...):", np.asarray(pred[0, :8]))

# --- 2. Train a small LM end-to-end --------------------------------------
print("\n== 50-step LM training (reduced qwen3-14b) ==")
from repro.configs.base import ShapeConfig
from repro.data import make_pipeline
from repro.launch.mesh import make_test_mesh
from repro.models import registry
from repro.runtime import Trainer, TrainConfig

bundle = registry.build("qwen3-14b", reduced=True)
mesh = make_test_mesh((jax.device_count(), 1), ("data", "model"))
tcfg = TrainConfig(num_steps=50, log_every=10, peak_lr=1e-3)
trainer = Trainer(bundle.model, mesh, tcfg)
pipe = make_pipeline(bundle.cfg, ShapeConfig("qs", 64, 8, "train"),
                     num_steps=50)
state = trainer.run(pipe)
hist = state["_history"]
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"({len(hist)} records)")
assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
print("OK")
