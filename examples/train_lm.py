"""End-to-end training driver: ~100M-param LM for a few hundred steps,
with checkpoint-restart, prefetch, straggler monitoring — the production
loop at laptop scale.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ShapeConfig
from repro.configs import llama3_2_3b
from repro.data import make_pipeline
from repro.launch.mesh import make_test_mesh
from repro.models import registry
from repro.runtime import Trainer, TrainConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    args = p.parse_args()

    # a ~100M-param llama3-family config (wider than the smoke `reduced()`)
    cfg = dataclasses.replace(
        llama3_2_3b.CONFIG, n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=2048, vocab=32_000, head_dim=64, max_seq=1024,
        param_dtype="float32", act_dtype="float32")
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    model = registry.build_model(cfg)
    mesh = make_test_mesh((jax.device_count(), 1), ("data", "model"))
    tcfg = TrainConfig(
        num_steps=args.steps, log_every=20, peak_lr=3e-4, warmup_steps=30,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, remat="full")
    trainer = Trainer(model, mesh, tcfg)
    state, start = trainer.maybe_restore()
    if start:
        print(f"resuming from checkpoint at step {start}")
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    pipe = make_pipeline(cfg, shape, start_step=start,
                         num_steps=args.steps - start,
                         sharding=trainer.shardings["batch"])
    state = trainer.run(pipe, start_step=start, state=state)
    hist = state["_history"]
    print("loss trajectory:",
          [f"{h['step']}:{h['loss']:.3f}" for h in hist])
    toks = args.steps * args.batch * args.seq
    print(f"trained on {toks/1e6:.1f}M tokens; "
          f"final loss {hist[-1]['loss']:.3f} (start {hist[0]['loss']:.3f})")
    if trainer.monitor.events:
        print("straggler events:", trainer.monitor.events)


if __name__ == "__main__":
    main()
