"""The paper's experiments, interactively: Fig. 2 roofline, Table II
reductions, and the reshuffle-injection mechanism (§IV.D.2).

Run:  PYTHONPATH=src python examples/vector_unit_demo.py
"""
import numpy as np
import jax.numpy as jnp

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.vu_model import (TABLE_II, matmul_cycles, reduction_cycles)
from repro.core import vrf


def fig2():
    print("== Fig. 2: fmatmul utilization vs n, lanes ==")
    print(f"{'n':>5} " + " ".join(f"l={l:<4}" for l in (2, 4, 8, 16)))
    for n in (16, 32, 64, 128, 256):
        row = [matmul_cycles(n, l)["utilization"] for l in (2, 4, 8, 16)]
        print(f"{n:>5} " + " ".join(f"{u:5.2f}" for u in row))
    print("(>0.985 at n=128, l=2 — the paper's headline)")


def table2():
    print("\n== Table II: reduction cycles (model vs paper) ==")
    for (lanes, vlb), (p8, p64) in sorted(TABLE_II.items()):
        m8 = reduction_cycles(vlb, lanes, 1)["model_cycles"]
        m64 = reduction_cycles(vlb, lanes, 8)["model_cycles"]
        print(f"  {lanes:>2} lanes {vlb:>5}B: model {m8:5.1f}/{m64:5.1f} "
              f"paper {p8}/{p64}")


def reshuffle_demo():
    print("\n== §IV.D.2: reshuffle injection on EEW change ==")
    f = vrf.VectorRegisterFile(vlen_bits=512, lanes=4)
    img = jnp.arange(64, dtype=jnp.uint8)
    f.write(1, img, eew=8)                        # 64-bit write
    f.write(1, img + 100, eew=2, vl=8)            # partial 16-bit write
    print("  reshuffles injected:", f.stats["reshuffles"])
    out = np.asarray(f.read_mem_image(1))
    assert (out[:16] == np.asarray(img + 100)[:16]).all()
    assert (out[16:] == np.asarray(img)[16:]).all()
    print("  body updated, tail preserved (tail-undisturbed) ✓")


if __name__ == "__main__":
    fig2()
    table2()
    reshuffle_demo()
