"""Batched serving example: prefill + queued decode across families.

Serves three different architectures (dense, MoE, SSM) through the same
driver surface — the C6 dispatch queue keeps decode steps in flight.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.launch.serve import generate
from repro.models import registry


def main():
    rng = np.random.default_rng(0)
    for arch in ("llama3.2-3b", "qwen2-moe-a2.7b", "mamba2-2.7b"):
        bundle = registry.build(arch, reduced=True)
        cfg = bundle.cfg
        params = jax.jit(bundle.model.init)(jax.random.PRNGKey(0))
        prompts = rng.integers(0, cfg.vocab, (4, 24)).astype(np.int32)
        t0 = time.perf_counter()
        toks = generate(bundle, params, prompts, gen_tokens=24, depth=2)
        dt = time.perf_counter() - t0
        assert toks.shape == (4, 24)
        assert (toks >= 0).all() and (toks < cfg.vocab).all()
        print(f"{arch:18s} 4 reqs x 24 tokens in {dt:5.2f}s "
              f"({4*24/dt:6.1f} tok/s)  first: {toks[0][:8]}")
    print("OK")


if __name__ == "__main__":
    main()
