"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json.  Usage:
    PYTHONPATH=src python experiments/make_report.py > /tmp/tables.md
"""
import glob
import json
import os
import sys


def load(tag_filter=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(
            os.path.dirname(__file__), "dryrun", "*.json"))):
        r = json.load(open(f))
        if tag_filter and r.get("tag") not in tag_filter:
            continue
        recs.append(r)
    return recs


def fmt_roofline_table(recs, mesh="single"):
    rows = []
    for r in recs:
        if r.get("skipped") or r.get("failed") or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], rf["dominant"][:4],
            1e3 * rf["compute_s"], 1e3 * rf["memory_s"],
            1e3 * rf["collective_s"], rf["roofline_fraction"],
            rf["useful_flops_ratio"],
            r.get("memory", {}).get("per_chip_gib", float("nan"))))
    rows.sort()
    out = ["| arch | shape | dom | compute ms | memory ms | coll ms | "
           "roofline frac | MODEL/HLO flops | GiB/chip |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a, s, d, c, m, w, f, u, g in rows:
        out.append(f"| {a} | {s} | {d} | {c:.1f} | {m:.1f} | {w:.1f} | "
                   f"{f:.4f} | {u:.2f} | {g:.1f} |")
    return "\n".join(out)


def fmt_skips(recs):
    out = []
    seen = set()
    for r in recs:
        if r.get("skipped") and (r["arch"], r["shape"]) not in seen:
            seen.add((r["arch"], r["shape"]))
            out.append(f"| {r['arch']} | {r['shape']} | {r['reason'][:80]} |")
    return "\n".join(["| arch | shape | reason |", "|---|---|---|"] + out)


def fmt_multi_pod(recs):
    """single vs multi per (arch, shape): wire ratio proves the pod axis."""
    single = {(r["arch"], r["shape"]): r for r in recs
              if r["mesh"] == "single" and "roofline" in r}
    out = ["| arch | shape | bound ms (256c) | bound ms (512c) | scaling |",
           "|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "multi" or "roofline" not in r:
            continue
        s = single.get((r["arch"], r["shape"]))
        if not s:
            continue
        bs = 1e3 * max(s["roofline"][k] for k in
                       ("compute_s", "memory_s", "collective_s"))
        bm = 1e3 * max(r["roofline"][k] for k in
                       ("compute_s", "memory_s", "collective_s"))
        out.append(f"| {r['arch']} | {r['shape']} | {bs:.1f} | {bm:.1f} | "
                   f"{bs/max(bm,1e-9):.2f}x |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    recs = load({which})
    print(f"## {which} — single-pod (16×16 = 256 chips)\n")
    print(fmt_roofline_table(recs, "single"))
    print(f"\n## {which} — multi-pod scaling (2×16×16 = 512 chips)\n")
    print(fmt_multi_pod(recs))
    if which == "baseline":
        print("\n## documented skips\n")
        print(fmt_skips(load()))
